"""Straggler detection + execution-skew statistics.

The paper's Fig. 14 measures inter-node execution skew under
communication-aware vs -oblivious scheduling; this monitor computes the
same statistic online from per-step wall times and flags persistent
stragglers (steps slower than median * threshold), the trigger for
mitigation (re-shard / evict) at cluster scale.
"""
from __future__ import annotations

import statistics
from collections import deque


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 1.5):
        self.window = deque(maxlen=window)
        self.threshold = threshold
        self.flags = 0

    def record(self, step_time: float):
        self.window.append(step_time)
        if len(self.window) >= 10:
            med = statistics.median(self.window)
            if step_time > self.threshold * med:
                self.flags += 1
                return True
        return False

    @property
    def skew(self) -> float:
        """max/median - 1 over the window (the Fig. 14 metric)."""
        if len(self.window) < 2:
            return 0.0
        med = statistics.median(self.window)
        return max(self.window) / med - 1.0 if med > 0 else 0.0

    def summary(self):
        if not self.window:
            return {}
        return {"median_s": statistics.median(self.window),
                "max_s": max(self.window),
                "skew": self.skew,
                "flags": self.flags}
