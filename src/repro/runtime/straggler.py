"""Straggler detection + execution-skew telemetry -> fused-op schedules.

The paper's Fig. 14 measures inter-node execution skew under
communication-aware vs -oblivious scheduling.  This module closes that
loop at run time:

  1. :class:`StragglerMonitor` — per-process step-time window: flags steps
     slower than ``threshold x`` the median of the *other* samples in the
     window (the current step is excluded from its own baseline, which
     would bias detection at small windows), and exposes a windowed flag
     rate so a recovered rank stops reading as a straggler.
  2. :class:`SkewEstimator` — cross-rank: per-rank EWMA step times
     (all-gathered over each ring axis by the host runtime) are reduced
     through the discrete-event schedule model
     (:func:`repro.core.scheduling.best_skew_rotation`) to one integer
     schedule rotation per mesh axis — the ``FusionConfig.skew`` bucket.
  3. :class:`SkewScheduler` — bucket -> re-jit: fused-op schedules are
     baked into the lowered HLO, so a bucket change requires rebuilding
     the jitted step.  The scheduler memoizes one build per bucket, so a
     changed bucket triggers exactly one re-jit and returning to a
     previously seen bucket costs nothing.

On a multi-host deployment the per-rank times in step 2 come from
:class:`ProcessTelemetry` — a process-level all-gather
(``multihost_utils.process_allgather``) of the local
``StragglerMonitor`` EWMA, expanded to the per-device vector the
estimator wants; single-process harnesses inject times directly (see
``benchmarks/bench_skew.py``).
"""
from __future__ import annotations

import statistics
from collections import deque
from typing import Callable, Mapping, Sequence

from repro.core.scheduling import (best_skew_rotation, modeled_execution_skew,
                                   skew_statistic)


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 1.5,
                 min_baseline: int = 9, ewma_alpha: float = 0.25):
        self.window = deque(maxlen=window)
        self.flag_window = deque(maxlen=window)
        self.threshold = threshold
        self.min_baseline = min_baseline
        self.ewma_alpha = ewma_alpha
        self.ewma: float | None = None
        self.flags = 0

    def record(self, step_time: float) -> bool:
        # the baseline is the window *before* this step: a sample must not
        # vote on its own outlier-ness (at small windows a slow step drags
        # the median up enough to mask itself)
        baseline = list(self.window)
        self.window.append(step_time)
        a = self.ewma_alpha
        self.ewma = (step_time if self.ewma is None
                     else (1 - a) * self.ewma + a * step_time)
        flagged = False
        if len(baseline) >= self.min_baseline:
            med = statistics.median(baseline)
            flagged = step_time > self.threshold * med
        self.flag_window.append(flagged)
        if flagged:
            self.flags += 1
        return flagged

    @property
    def flag_rate(self) -> float:
        """Fraction of the last ``window`` steps flagged — decays to 0 when
        a rank recovers (the cumulative ``flags`` count never does)."""
        if not self.flag_window:
            return 0.0
        return sum(self.flag_window) / len(self.flag_window)

    @property
    def skew(self) -> float:
        """max/median - 1 over the window (the Fig. 14 metric)."""
        if len(self.window) < 2:
            return 0.0
        med = statistics.median(self.window)
        return max(self.window) / med - 1.0 if med > 0 else 0.0

    def summary(self):
        if not self.window:
            return {}
        return {"median_s": statistics.median(self.window),
                "max_s": max(self.window),
                "skew": self.skew,
                "flags": self.flags,
                "flag_rate": self.flag_rate,
                "ewma_s": self.ewma}


class SkewEstimator:
    """Per-rank EWMA step times -> integer schedule rotation per ring axis.

    ``axis_sizes`` maps each ring axis name to its world size (e.g.
    ``{"data": 2, "model": 4}``).  :meth:`observe` takes one *per-rank*
    step-time vector in mesh row-major order (the flat device order of the
    mesh); per-axis times are reduced by averaging over the other axes, so
    a straggling device skews exactly the rings it sits on.  The rotation
    for an axis is the ``skew`` minimizing the modeled schedule-induced
    execution skew under the measured EWMA times
    (:func:`repro.core.scheduling.best_skew_rotation`), with a dead band:
    rotations only move once the modeled improvement over the current
    bucket exceeds ``hysteresis``, so jitter cannot thrash the re-jit
    loop.  ``link_scales`` optionally maps an axis to per-link cost
    multipliers (static topology — a slow DCN/pod-boundary link), which
    is what couples the measured straggler *position* to a non-trivial
    rotation.
    """

    def __init__(self, axis_sizes: Mapping[str, int], *, alpha: float = 0.25,
                 min_obs: int = 2, hysteresis: float = 0.005,
                 schedule: str = "comm_aware",
                 link_scales: Mapping[str, Sequence[float]] | None = None,
                 reduce_every: int = 1):
        """``reduce_every``: run the rotation sweep only every N
        observations (the EWMA moves slowly, so re-reducing each step is
        wasted work — the sweep is O(world^3) Python per axis, which at
        cluster scale should not sit in the per-step loop)."""
        self.axis_sizes = dict(axis_sizes)
        self.link_scales = {a: list(v) for a, v in (link_scales or {}).items()}
        self.world = 1
        for s in self.axis_sizes.values():
            self.world *= s
        self.alpha = alpha
        self.min_obs = min_obs
        self.hysteresis = hysteresis
        self.schedule = schedule
        self.reduce_every = max(1, int(reduce_every))
        self.ewma: list[float] | None = None
        self.n_obs = 0
        self._rotation = {a: 0 for a in self.axis_sizes}

    def observe(self, per_rank_times: Sequence[float]) -> None:
        t = [float(x) for x in per_rank_times]
        if len(t) != self.world:
            raise ValueError(f"expected {self.world} per-rank times, got "
                             f"{len(t)}")
        if any(x <= 0 for x in t):
            raise ValueError("step times must be positive")
        if self.ewma is None:
            self.ewma = t
        else:
            a = self.alpha
            self.ewma = [(1 - a) * e + a * x for e, x in zip(self.ewma, t)]
        self.n_obs += 1
        if self.n_obs == self.min_obs or self.n_obs % self.reduce_every == 0:
            self._reduce()

    def _axis_times(self, axis: str) -> list[float]:
        """Mean EWMA per position along ``axis`` (row-major mesh order)."""
        sizes = list(self.axis_sizes.values())
        names = list(self.axis_sizes)
        i = names.index(axis)
        stride = 1
        for s in sizes[i + 1:]:
            stride *= s
        n = sizes[i]
        sums = [0.0] * n
        counts = [0] * n
        for flat, t in enumerate(self.ewma):
            pos = (flat // stride) % n
            sums[pos] += t
            counts[pos] += 1
        return [s / c for s, c in zip(sums, counts)]

    def _reduce(self) -> None:
        if self.n_obs < self.min_obs:
            return
        for axis, n in self.axis_sizes.items():
            if n < 2:
                continue
            times = self._axis_times(axis)
            ls = self.link_scales.get(axis)
            cand = best_skew_rotation(n, times, schedule=self.schedule,
                                      link_scale=ls)
            cur = self._rotation[axis]
            if cand == cur:
                continue
            s_cur = modeled_execution_skew(n, self.schedule, cur, times,
                                           link_scale=ls)
            s_new = modeled_execution_skew(n, self.schedule, cand, times,
                                           link_scale=ls)
            if s_cur - s_new > self.hysteresis:
                self._rotation[axis] = cand

    def rotation(self, axis: str) -> int:
        """Current schedule rotation bucket for one ring axis."""
        return self._rotation[axis]

    def rotations(self) -> dict[str, int]:
        return dict(self._rotation)

    def axis_skew(self, axis: str) -> float:
        """Measured max/median - 1 of the EWMA times along ``axis``."""
        if self.ewma is None:
            return 0.0
        return skew_statistic(self._axis_times(axis))


def _default_process_allgather(local: float) -> list[float]:
    """All-gather one scalar across processes, ordered by process index.
    Single-process (the CI/laptop case) short-circuits without touching
    the distributed runtime."""
    import jax

    if jax.process_count() == 1:
        return [float(local)]
    import numpy as np
    from jax.experimental import multihost_utils

    arr = multihost_utils.process_allgather(np.float32(local))
    return [float(v) for v in np.asarray(arr).reshape(-1)]


class ProcessTelemetry:
    """Multi-host ``per_rank_times`` provider for :class:`~repro.runtime.
    fault_tolerance.TrainSupervisor`: all-gathers the local
    :class:`StragglerMonitor` EWMA across processes and replicates each
    process's time over its local devices (mesh device order is
    process-major — ``jax.devices()`` — which is how the launchers build
    their meshes), yielding the per-rank vector ``SkewEstimator`` reduces.

    The EWMA (not the raw step time) is what travels: it is already
    jitter-smoothed, so one slow GC pause on a healthy host cannot flip
    the schedule bucket.  Before the monitor has any sample the current
    step time stands in.  ``allgather`` is injectable for tests (and for
    runtimes with their own gather primitive).
    """

    def __init__(self, monitor: StragglerMonitor, world: int, *,
                 allgather: Callable[[float], Sequence[float]] | None = None):
        self.monitor = monitor
        self.world = int(world)
        self.allgather = allgather or _default_process_allgather

    def __call__(self, dt: float) -> list[float]:
        local = self.monitor.ewma if self.monitor.ewma is not None else dt
        per_proc = [float(t) for t in self.allgather(float(local))]
        n_proc = len(per_proc)
        if n_proc == 0 or self.world % n_proc:
            raise ValueError(
                f"cannot spread {n_proc} process times over a world of "
                f"{self.world} devices (world must be a process multiple)")
        rep = self.world // n_proc
        return [t for t in per_proc for _ in range(rep)]


class SkewScheduler:
    """Bucket-keyed re-jit loop: telemetry in, current jitted fn out.

    ``build(skew: int) -> fn`` builds (jits) the step for one skew bucket
    — typically ``lambda s: jax.jit(make_step(ctx.with_fusion(
    dataclasses.replace(fusion, skew=s))))``.  Builds are memoized per
    bucket: a changed bucket triggers exactly one rebuild, and flipping
    back to an already-seen bucket reuses the compiled step.
    """

    def __init__(self, build: Callable[[int], Callable],
                 estimator: SkewEstimator, axis: str):
        self.build = build
        self.estimator = estimator
        self.axis = axis
        self._fns: dict[int, Callable] = {}
        self.bucket = 0
        self.rebuilds = 0

    def fn(self) -> Callable:
        """The jitted step for the current bucket (building on first use)."""
        if self.bucket not in self._fns:
            self._fns[self.bucket] = self.build(self.bucket)
            self.rebuilds += 1
        return self._fns[self.bucket]

    def invalidate(self) -> None:
        """Drop every memoized build.  Needed when something *outside* the
        bucket key changes what ``build`` bakes into the trace — e.g. the
        degradation policy quarantined an op family, so the cached steps
        still carry the fused path.  The next ``fn()`` re-jits."""
        self._fns.clear()

    def observe(self, per_rank_times: Sequence[float]) -> bool:
        """Feed one all-gathered per-rank step-time vector; returns True
        when the schedule bucket changed (callers swap in ``fn()``)."""
        self.estimator.observe(per_rank_times)
        new = self.estimator.rotation(self.axis)
        if new == self.bucket:
            return False
        self.bucket = new
        return True
