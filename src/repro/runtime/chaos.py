"""Chaos engineering: seeded fault injection for the fused rings.

The fused compute-collective kernels put communication on the critical
path of every step, so production failures surface *inside* the rings:
a slow link stalls every rank, a transient timeout kills the step, a
flipped wire bit poisons the reduction, and a lost rank takes the whole
ring down until the mesh is reshaped.  This module reproduces that fault
model deterministically so the recovery machinery
(:mod:`repro.runtime.fault_tolerance`, :mod:`repro.core.degrade`,
:mod:`repro.runtime.elastic`) can be validated end to end:

  slow_link - a transient slow rank/link: the step stalls for ``delay_s``
              (the straggler telemetry sees it like any real straggler).
  timeout   - a transient collective timeout: the step raises
              :class:`CollectiveTimeout` (the NCCL-watchdog analogue);
              the supervisor restores and retries with backoff.
  rank_fail - a transient rank kill: same recovery surface as timeout
              (restart from checkpoint), logged as a distinct kind.
  nan_wire  - a corrupt wire payload: the ``nth_send``-th ring/A2A send
              of the step carries NaNs, injected at the
              :mod:`repro.core.collectives` boundary through the
              trace-time wire-fault hook (zero-cost when disabled: the
              hook is a module-level ``None`` check at trace time, so
              the lowered HLO is bit-identical to the clean build).
  rank_loss - a *permanent* rank loss: raises :class:`RankLost`.
              Recovery is not a restart but an elastic shrink
              (:func:`repro.runtime.elastic.shrink_context`) — the
              supervisor re-shards live state onto the surviving mesh
              and the serve engine drain-reshards its in-flight slots.

Everything is seeded: :meth:`FaultPlan.from_rate` draws its schedule
from ``numpy.random.default_rng(seed)``, so a chaos scenario replays
bit-identically — the property the chaos test lane and
``benchmarks/bench_chaos.py`` pin.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.core.collectives import set_wire_fault_hook

FAULT_KINDS = ("slow_link", "timeout", "rank_fail", "nan_wire", "rank_loss")
#: kinds the restart path recovers from (rank_loss needs an elastic shrink)
TRANSIENT_KINDS = ("slow_link", "timeout", "rank_fail", "nan_wire")


class CollectiveTimeout(RuntimeError):
    """A transient collective timeout (the NCCL-watchdog analogue)."""


class RankLost(RuntimeError):
    """A permanent rank loss; carries the lost flat rank index."""

    def __init__(self, rank: int, msg: str | None = None):
        super().__init__(msg or f"rank {rank} lost permanently")
        self.rank = int(rank)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``delay_s`` is the slow-link stall;
    ``nth_send`` picks which wire send of the traced step a ``nan_wire``
    event corrupts (trace order across every ring hop / A2A send)."""

    step: int
    kind: str
    rank: int = 0
    delay_s: float = 0.0
    nth_send: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")


class FaultPlan:
    """A seeded, schedule-driven fault plan: ``at(step)`` returns the
    events scheduled for that step (possibly several).  Construct with
    explicit events for scenario tests, or :meth:`from_rate` for a
    Bernoulli fault process at a target per-step rate."""

    def __init__(self, events: Iterable[FaultEvent], seed: int = 0):
        self.seed = int(seed)
        self.events = tuple(sorted(events, key=lambda e: e.step))
        by_step: dict[int, list[FaultEvent]] = {}
        for e in self.events:
            by_step.setdefault(e.step, []).append(e)
        self._by_step = {s: tuple(v) for s, v in by_step.items()}

    @classmethod
    def from_rate(cls, seed: int, rate: float, num_steps: int, *,
                  kinds: Sequence[str] = ("timeout", "slow_link"),
                  world: int = 8, delay_s: float = 0.01,
                  nan_nth_send: int = 0) -> "FaultPlan":
        """Deterministic Bernoulli schedule: each step faults with
        probability ``rate``, the kind drawn uniformly from ``kinds``.
        ``rank_loss`` is deliberately not a default kind — a permanent
        loss needs an elastic-shrink handler, so callers opt in."""
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {rate}")
        rng = np.random.default_rng(seed)
        events = []
        for step in range(int(num_steps)):
            if rng.random() >= rate:
                continue
            kind = kinds[int(rng.integers(len(kinds)))]
            events.append(FaultEvent(
                step=step, kind=kind, rank=int(rng.integers(world)),
                delay_s=float(delay_s), nth_send=int(nan_nth_send)))
        return cls(events, seed=seed)

    def at(self, step: int) -> tuple[FaultEvent, ...]:
        return self._by_step.get(int(step), ())

    def __len__(self) -> int:
        return len(self.events)

    def summary(self) -> dict:
        counts: dict[str, int] = {}
        for e in self.events:
            counts[e.kind] = counts.get(e.kind, 0) + 1
        return {"seed": self.seed, "n_events": len(self.events),
                "by_kind": counts}


# ---------------------------------------------------------------------------
# wire-level fault injection (the collectives-boundary hook)
# ---------------------------------------------------------------------------
class WireFaultInjector:
    """Trace-time payload corruptor installed at the
    :func:`repro.core.collectives.ring_permute` /
    :func:`~repro.core.collectives.all_gather_wire` boundary.

    Counts float payload sends in trace order and replaces the
    ``nth_send``-th with ``value`` (NaN by default) — the repro of a
    corrupt link.  Integer payloads (routing ids) are never touched.
    ``fired`` records whether the target send existed in the trace, so a
    scenario can assert its fault actually landed.
    """

    def __init__(self, nth_send: int = 0, value: float = float("nan")):
        self.nth = int(nth_send)
        self.value = float(value)
        self.count = 0
        self.fired = False

    def __call__(self, leaf):
        import jax.numpy as jnp

        if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            return leaf
        i = self.count
        self.count += 1
        if i != self.nth:
            return leaf
        self.fired = True
        return jnp.full_like(leaf, jnp.asarray(self.value, leaf.dtype))


@contextlib.contextmanager
def wire_faults(nth_send: int = 0, value: float = float("nan")):
    """Install a :class:`WireFaultInjector` for the duration of one trace.

    The corruption is baked into whatever is *traced* inside the block,
    so callers jit a **fresh** step function inside the context (an
    already-compiled function replays its clean cached trace — see
    ``TrainSupervisor.rebuild_step``).  Yields the injector so callers
    can assert ``fired``.
    """
    inj = WireFaultInjector(nth_send=nth_send, value=value)
    prev = set_wire_fault_hook(inj)
    try:
        yield inj
    finally:
        set_wire_fault_hook(prev)


# ---------------------------------------------------------------------------
# CLI plumbing (shared by launch/train.py and launch/serve.py)
# ---------------------------------------------------------------------------
def parse_chaos_spec(spec: str, *, num_steps: int) -> FaultPlan:
    """Parse the ``--chaos`` flag.

    Two forms:
      ``rate=0.05[,seed=0][,kinds=timeout+slow_link][,delay=0.01]``
          seeded Bernoulli schedule over ``num_steps``.
      ``at=7:timeout+20:nan_wire+40:rank_loss[,seed=0][,delay=0.01]``
          explicit ``step:kind`` events (the scenario form).
    """
    fields: dict[str, str] = {}
    for part in spec.split(","):
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad --chaos field {part!r} (want key=value)")
        k, v = part.split("=", 1)
        fields[k.strip()] = v.strip()
    seed = int(fields.get("seed", 0))
    delay = float(fields.get("delay", 0.01))
    if "at" in fields:
        events = []
        for ev in fields["at"].split("+"):
            s, kind = ev.split(":")
            events.append(FaultEvent(step=int(s), kind=kind, delay_s=delay))
        return FaultPlan(events, seed=seed)
    if "rate" not in fields:
        raise ValueError("--chaos needs either rate=... or at=... "
                         f"(got {spec!r})")
    kinds = tuple(fields.get("kinds", "timeout+slow_link").split("+"))
    return FaultPlan.from_rate(seed, float(fields["rate"]), num_steps,
                               kinds=kinds, delay_s=delay)


def add_chaos_cli_args(ap) -> None:
    """Install the shared ``--chaos`` / ``--degrade`` flags."""
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="seeded fault injection: 'rate=0.05,seed=0,"
                         "kinds=timeout+slow_link+nan_wire' for a Bernoulli "
                         "schedule, or 'at=7:timeout+40:rank_loss' for "
                         "explicit step:kind events; transient faults "
                         "exercise the checkpoint/restart path, nan_wire "
                         "corrupts a real ring payload, rank_loss triggers "
                         "the elastic shrink")
    ap.add_argument("--degrade", action="store_true",
                    help="enable the degradation policy: repeated fused-"
                         "path failures or NaN losses quarantine the "
                         "offending (op, shape) decisions and fall back to "
                         "the bulk collectives, re-probing after a "
                         "cool-down")


def build_fault_plan(spec: str | None, *, num_steps: int) -> FaultPlan | None:
    return None if spec is None else parse_chaos_spec(spec,
                                                      num_steps=num_steps)
