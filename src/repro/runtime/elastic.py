"""Elastic scaling: re-shard a live state pytree onto a different mesh.

When the world shrinks (lost pod) or grows (capacity arrives), training
resumes by (1) re-building the mesh, (2) re-deriving NamedShardings from
the *logical* spec tree — which is mesh-independent — and (3) placing
either the live state or the latest checkpoint with the new shardings.
Divisibility is re-checked; batch sizes rescale to keep per-device load.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.parallel.sharding import ParallelContext


def reshard_tree(tree, logical_specs, new_ctx: ParallelContext):
    """Place every leaf with the sharding its logical spec implies on the
    new mesh.  Works device->device (live resize) and host->device
    (restore)."""
    def leaf_sharding(spec):
        return new_ctx.sharding(*spec)

    is_spec = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    shardings = jax.tree.map(leaf_sharding, logical_specs, is_leaf=is_spec)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings), shardings


def rescale_batch(global_batch: int, old_dp: int, new_dp: int) -> int:
    """Keep per-device batch constant under world resize."""
    per_dev = max(1, global_batch // old_dp)
    return per_dev * new_dp


def check_divisibility(ctx: ParallelContext, d_ff: int, vocab: int, seq: int):
    problems = []
    if d_ff % ctx.tp:
        problems.append(f"d_ff {d_ff} % tp {ctx.tp}")
    if vocab % ctx.tp:
        problems.append(f"vocab {vocab} % tp {ctx.tp}")
    if seq % ctx.tp:
        problems.append(f"seq {seq} % tp {ctx.tp}")
    return problems
