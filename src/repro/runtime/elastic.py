"""Elastic scaling: re-shard a live state pytree onto a different mesh.

When the world shrinks (lost pod) or grows (capacity arrives), training
resumes by (1) re-building the mesh, (2) re-deriving NamedShardings from
the *logical* spec tree — which is mesh-independent — and (3) placing
either the live state or the latest checkpoint with the new shardings.
Divisibility is re-checked; batch sizes rescale to keep per-device load.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.parallel.sharding import ParallelContext


def shrink_context(ctx: ParallelContext, factor: int = 2,
                   axis: str | None = None, fusion=None,
                   lost=None) -> ParallelContext:
    """A smaller-world ``ParallelContext`` after losing capacity.

    Shrinks one mesh axis by ``factor`` and rebuilds the mesh from the
    first surviving devices (flattened major-to-minor order — the healthy
    prefix of the old world).  Prefers a data-parallel axis: dp shrink
    changes only how many batch shards run concurrently, while tp shrink
    changes every sharded matmul's decomposition.  Falls back to the tp
    axis when no dp axis is divisible.  The hardware model carries over
    (link classes attach to axis *names*, which survive the resize).

    ``lost`` names the dead devices as flat indices into the flattened
    old world (e.g. ``range(0, 4)`` when the process owning the *first*
    four devices died — a non-prefix survivor set).  The new mesh is
    then built from the first ``keep`` devices that are **not** lost,
    instead of blindly taking the prefix — taking the prefix after
    losing device 0 would rebuild the mesh around dead hardware.
    """
    if factor < 2:
        raise ValueError(f"shrink factor must be >= 2, got {factor}")
    if axis is None:
        for cand in tuple(ctx.dp_axes) + (ctx.tp_axis,):
            if ctx.mesh.shape[cand] % factor == 0 and \
                    ctx.mesh.shape[cand] >= factor:
                axis = cand
                break
        if axis is None:
            raise ValueError(
                f"no mesh axis divisible by {factor} in {dict(ctx.mesh.shape)}")
    elif ctx.mesh.shape[axis] % factor or ctx.mesh.shape[axis] < factor:
        raise ValueError(f"axis {axis!r} ({ctx.mesh.shape[axis]}) not "
                         f"divisible by shrink factor {factor}")
    names = ctx.mesh.axis_names
    shape = [ctx.mesh.shape[n] // factor if n == axis else ctx.mesh.shape[n]
             for n in names]
    keep = int(np.prod(shape))
    flat = np.asarray(ctx.mesh.devices).reshape(-1)
    if lost is not None:
        dead = {int(i) for i in lost}
        bad = dead - set(range(flat.size))
        if bad:
            raise ValueError(f"lost indices {sorted(bad)} outside the "
                             f"flattened world of {flat.size} devices")
        flat = np.asarray([d for i, d in enumerate(flat) if i not in dead])
        if flat.size < keep:
            raise ValueError(
                f"only {flat.size} devices survive ({len(dead)} lost) but "
                f"the shrunk mesh {dict(zip(names, shape))} needs {keep}; "
                f"shrink by a larger factor")
    devices = flat[:keep].reshape(shape)
    new_mesh = Mesh(devices, names)
    if fusion is None:
        fusion = ctx.fusion
    return dataclasses.replace(ctx, mesh=new_mesh, fusion=fusion)


def reshard_tree(tree, logical_specs, new_ctx: ParallelContext):
    """Place every leaf with the sharding its logical spec implies on the
    new mesh.  Works device->device (live resize) and host->device
    (restore)."""
    def leaf_sharding(spec):
        return new_ctx.sharding(*spec)

    is_spec = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    shardings = jax.tree.map(leaf_sharding, logical_specs, is_leaf=is_spec)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings), shardings


def rescale_batch(global_batch: int, old_dp: int, new_dp: int,
                  microbatches: int = 1) -> int:
    """Keep per-device batch constant under world resize.

    ``global_batch`` must shard evenly over ``old_dp`` — otherwise "per-
    device batch" is ill-defined and the round trip does not invert
    (e.g. batch 4 on dp 8 clamps to 1/device, returning 8 on re-grow).
    That silent 2x batch change corrupts the learning-rate/batch coupling,
    so it warns loudly instead of passing unnoticed.

    ``microbatches`` is the per-step grad-accumulation split: when a dp
    shrink drops the rescaled batch below (or off a multiple of) the
    microbatch count, some microbatches would be empty and the split
    no longer divides — the new batch is rounded **up** to the next
    multiple so accumulation stays well-formed, again with a loud
    warning (the effective batch grew; the LR schedule may need a
    touch)."""
    if global_batch % old_dp:
        warnings.warn(
            f"global batch {global_batch} does not divide over dp={old_dp}; "
            f"per-device batch clamps to {max(1, global_batch // old_dp)} "
            f"and the effective global batch changes under resize",
            RuntimeWarning, stacklevel=2)
    per_dev = max(1, global_batch // old_dp)
    new_batch = per_dev * new_dp
    if microbatches > 1 and new_batch % microbatches:
        rounded = -(-new_batch // microbatches) * microbatches
        warnings.warn(
            f"rescaled batch {new_batch} (dp {old_dp} -> {new_dp}) no "
            f"longer divides into {microbatches} microbatches; rounding up "
            f"to {rounded} — the effective global batch changes under "
            f"resize", RuntimeWarning, stacklevel=2)
        new_batch = rounded
    return new_batch


def check_divisibility(ctx: ParallelContext, d_ff: int, vocab: int, seq: int):
    problems = []
    if d_ff % ctx.tp:
        problems.append(f"d_ff {d_ff} % tp {ctx.tp}")
    if vocab % ctx.tp:
        problems.append(f"vocab {vocab} % tp {ctx.tp}")
    if seq % ctx.tp:
        problems.append(f"seq {seq} % tp {ctx.tp}")
    return problems
