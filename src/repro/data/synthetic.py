"""Deterministic synthetic data generators (seeded, shardable).

LM batches follow a Zipf-ish unigram distribution with local n-gram
structure so the loss actually decreases during the example runs; DLRM
batches mirror the public DLRM data generator (uniform categorical +
normal dense) the paper evaluates with.
"""
from __future__ import annotations

import numpy as np


class LMBatches:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.rng = np.random.default_rng(seed)
        # fixed random bigram table gives learnable structure
        self._follow = np.random.default_rng(seed + 1).integers(
            0, vocab, size=(min(vocab, 4096),), dtype=np.int64)

    def __iter__(self):
        return self

    def __next__(self):
        zipf = self.rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(zipf - 1, self.vocab - 1).astype(np.int32)
        # inject bigram structure: half the positions follow the table
        mask = self.rng.random((self.batch, self.seq)) < 0.5
        nxt = self._follow[toks[:, :-1] % len(self._follow)].astype(np.int32)
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DLRMBatches:
    def __init__(self, n_tables: int, vocab: int, pooling: int, n_dense: int,
                 batch: int, seed: int = 0):
        self.p = (n_tables, vocab, pooling, n_dense, batch)
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self):
        t, v, L, nd, b = self.p
        return {
            "dense": self.rng.standard_normal((b, nd)).astype(np.float32),
            "indices": self.rng.integers(0, v, size=(b, t, L)).astype(np.int32),
            "labels": (self.rng.random(b) < 0.3).astype(np.float32),
        }
