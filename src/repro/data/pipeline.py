"""Input pipeline: sharded host loading + double-buffered device prefetch.

``prefetch`` keeps N batches in flight (device transfers are async in
JAX), hiding host->HBM time behind the previous step's compute — the
same overlap philosophy as the paper, applied at the input edge.
"""
from __future__ import annotations

import collections
from typing import Iterator

import jax


def shard_batch(batch, sharding_tree):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch, sharding_tree)


def prefetch(it: Iterator, sharding_tree, depth: int = 2):
    buf = collections.deque()

    def enqueue(n):
        for _ in range(n):
            try:
                batch = next(it)
            except StopIteration:
                return
            buf.append(shard_batch(batch, sharding_tree))

    enqueue(depth)
    while buf:
        yield buf.popleft()
        enqueue(1)
