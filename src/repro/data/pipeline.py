"""Input pipeline: sharded host loading + double-buffered device prefetch.

``prefetch`` keeps N batches in flight (device transfers are async in
JAX), hiding host->HBM time behind the previous step's compute — the
same overlap philosophy as the paper, applied at the input edge.
"""
from __future__ import annotations

import collections
from typing import Iterator

import jax


def shard_batch(batch, sharding_tree):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch, sharding_tree)


def prefetch(it: Iterator, sharding_tree, depth: int = 2):
    buf = collections.deque()

    def enqueue(n):
        for _ in range(n):
            try:
                batch = next(it)
            except StopIteration:
                return
            buf.append(shard_batch(batch, sharding_tree))

    enqueue(depth)
    while buf:
        yield buf.popleft()
        enqueue(1)


class ReplayBuffer:
    """Checkpoint-aligned batch replay for restart-on-failure training.

    A restored step must see the *same* batch it saw before the failure —
    a plain iterator cannot rewind, so restored runs silently skip ahead
    (different data, different final state).  This wrapper buffers every
    batch drawn since the last committed checkpoint; :meth:`rewind`
    re-serves from a restored step and :meth:`commit` (called when a
    checkpoint lands) drops batches that can never be replayed again, so
    memory is bounded by ``checkpoint_every`` batches.

    ``base_step`` anchors the first drawn batch to a step index (the
    supervisor's starting step) — in-process replay only; resuming a
    *fresh* process from a mid-run checkpoint needs a deterministic
    iterator re-seeded past the checkpoint, which is the data source's
    contract, not this buffer's.
    """

    def __init__(self, it: Iterator, base_step: int = 0):
        self._it = iter(it)
        self._buf: list = []        # batches for steps [base, base+len)
        self._base = int(base_step)
        self._cursor = 0            # next serve position, relative to base

    @property
    def step(self) -> int:
        """Step index the next :meth:`next_batch` call serves."""
        return self._base + self._cursor

    def next_batch(self):
        if self._cursor == len(self._buf):
            self._buf.append(next(self._it))  # StopIteration propagates
        b = self._buf[self._cursor]
        self._cursor += 1
        return b

    def rewind(self, step: int) -> None:
        """Re-serve from ``step`` (a restored checkpoint step)."""
        if not self._base <= step <= self._base + len(self._buf):
            raise ValueError(
                f"cannot rewind to step {step}: replay window is "
                f"[{self._base}, {self._base + len(self._buf)}] (batches "
                f"before the last committed checkpoint are dropped)")
        self._cursor = step - self._base

    def commit(self, step: int) -> None:
        """A checkpoint at ``step`` landed: batches for earlier steps can
        never be replayed again and are dropped."""
        drop = step - self._base
        if drop <= 0:
            return
        self._buf = self._buf[drop:]
        self._base = step
        self._cursor = max(0, self._cursor - drop)
